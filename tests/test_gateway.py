"""The SLO-aware dynamic-batching gateway: ladder coalescing, deadline
flush, per-request tier routing, SLO shedding with hysteresis, provenance —
and the acceptance contract: gateway answers are bitwise-identical to
direct ``knn_batch`` calls at the same tier (padding never leaks), with
pinned-epoch semantics per formed batch, under concurrent clients and a
background-ingest stream."""
import threading
import time

import numpy as np
import pytest

from repro.core import (Gateway, GatewayConfig, StreamConfig, StreamingIndex,
                        SummarizationConfig)
from repro.core.gateway import ladder
from repro.core.verify_engine import get_engine

LEN = 64
CFG = SummarizationConfig(series_len=LEN, n_segments=8, card_bits=6)


def _series(n, seed):
    rng = np.random.default_rng(seed)
    return rng.standard_normal((n, LEN)).astype(np.float32).cumsum(axis=1)


def _index(n_batches=8, bsz=300, **kw):
    idx = StreamingIndex(StreamConfig(scheme="BTP", summarization=CFG,
                                      buffer_entries=512, growth_factor=3,
                                      block_size=128, **kw))
    for b in range(n_batches):
        idx.ingest(_series(bsz, 100 + b), np.full(bsz, b, np.int64))
    return idx


@pytest.fixture(scope="module")
def idx():
    return _index()


def _gateway(idx, **kw):
    kw.setdefault("deadline_ms", 3.0)
    kw.setdefault("max_batch", 16)
    kw.setdefault("k", 5)
    return Gateway(idx, GatewayConfig(**kw))


# --------------------------------------------------------------- unit tier
def test_ladder_rungs_are_engine_batch_buckets():
    assert ladder(64) == (8, 16, 32, 64)
    assert ladder(16) == (8, 16)
    assert ladder(8) == (8,)
    # a non-bucket max still tops the ladder (the engine pads past it)
    assert ladder(24) == (8, 16, 24)


def test_max_batch_cannot_exceed_engine_chunk(idx):
    with pytest.raises(ValueError):
        Gateway(idx, GatewayConfig(max_batch=128))


def test_single_request_deadline_flush(idx):
    gw = _gateway(idx, deadline_ms=5.0)
    try:
        r = gw.submit(_series(1, 7)[0]).result(timeout=30)
        assert r.batch_size == 1
        assert r.padded_to == 8  # padded up to the rung floor
        assert r.tier_served == "exact" and not r.shed
        assert r.ids.shape == (5,)
        st = gw.snapshot_stats()
        assert st["deadline_flushes"] == 1 and st["full_flushes"] == 0
        assert st["batch_hist"] == {1: 1}
    finally:
        gw.close()


def test_full_rung_flushes_without_waiting_deadline(idx):
    # a long deadline: only a full top rung can flush this fast
    gw = _gateway(idx, deadline_ms=2_000.0, max_batch=8)
    try:
        Q = _series(8, 8)
        t0 = time.perf_counter()
        tix = [gw.submit(q) for q in Q]
        resps = [t.result(timeout=60) for t in tix]
        assert (time.perf_counter() - t0) < 100.0  # not the 2s deadline
        assert all(r.batch_size == 8 and r.padded_to == 8 for r in resps)
        assert gw.snapshot_stats()["full_flushes"] >= 1
    finally:
        gw.close()


def test_padding_never_leaks_parity_all_rungs(idx):
    """Every partial-batch size pads to its rung; answers must be bitwise
    equal to a direct call with ONLY the real queries."""
    gw = _gateway(idx, deadline_ms=2.0)
    try:
        for m in (1, 3, 5, 9, 13):
            Q = _series(m, 200 + m)
            resps = [t.result(timeout=60) for t in
                     [gw.submit(q) for q in Q]]
            vals, gids, _ = idx.knn_batch(Q, k=5)
            for i, r in enumerate(resps):
                assert np.array_equal(r.ids, gids[i])
                assert np.array_equal(r.vals, vals[i])
    finally:
        gw.close()


def test_mixed_tier_batch_splits_and_matches_direct_calls(idx):
    """One formed batch carrying exact + approx + windowed requests splits
    into per-(tier, n_blocks, k, window) sub-batches; each answer matches
    the direct batched call at the same tier bitwise."""
    gw = _gateway(idx, deadline_ms=20.0, max_batch=16)
    try:
        Q = _series(12, 31)
        tix = []
        for i in range(4):  # plain exact, whole history
            tix.append(gw.submit(Q[i]))
        for i in range(4, 8):  # recall-targeted -> approx tier
            tix.append(gw.submit(Q[i], target_recall=0.9))
        for i in range(8, 12):  # windowed exact
            tix.append(gw.submit(Q[i], window=(2, 6)))
        resps = [t.result(timeout=60) for t in tix]
        epochs = {r.epoch for r in resps}
        assert len(epochs) == 1  # ONE pinned epoch per formed batch
        assert all(r.batch_size == 12 for r in resps)
        ev, ei, _ = idx.knn_batch(Q[:4], k=5)
        nb = resps[4].n_blocks
        av, ai, _ = idx.knn_approx_batch(Q[4:8], k=5, n_blocks=nb)
        wv, wi, _ = idx.window_knn_batch(Q[8:12], 2, 6, k=5)
        for i in range(4):
            assert resps[i].tier_served == "exact"
            assert np.array_equal(resps[i].ids, ei[i])
            assert np.array_equal(resps[i].vals, ev[i])
            assert resps[4 + i].tier_served == "approx"
            assert np.array_equal(resps[4 + i].ids, ai[i])
            assert np.array_equal(resps[4 + i].vals, av[i])
            assert resps[8 + i].tier_served == "exact"
            assert np.array_equal(resps[8 + i].ids, wi[i])
            assert np.array_equal(resps[8 + i].vals, wv[i])
    finally:
        gw.close()


def test_deterministic_mixed_tenant_split(idx):
    """The same mixed-tenant submission (half strict-recall, half
    tight-latency) must route and split identically on every run."""
    def run_once():
        gw = _gateway(idx, deadline_ms=20.0, max_batch=16)
        try:
            Q = _series(8, 77)
            tix = []
            for i in range(4):
                tix.append(gw.submit(Q[i], target_recall=1.0))
            for i in range(4, 8):
                tix.append(gw.submit(Q[i], target_recall=0.9,
                                     latency_budget_ms=0.05))
            rs = [t.result(timeout=60) for t in tix]
            return [(r.tier_served, r.n_blocks, r.shed, r.conflict,
                     tuple(r.ids)) for r in rs]
        finally:
            gw.close()

    a, b = run_once(), run_once()
    assert a == b
    # strict-recall half stays exact and is never shed/conflicted
    assert all(t == ("exact",) + t[1:] and not t[2] and not t[3]
               for t in a[:4])
    # tight-latency half: capped n_blocks -> conflict -> marked shed
    assert all(t[0] == "approx" and t[2] and t[3] for t in a[4:])


def test_conflict_propagates_into_shed_decision(idx):
    """The recommender's 'latency cap makes the recall target unreachable'
    verdict must arrive as a structured flag and mark the answer shed even
    with no SLO pressure."""
    gw = _gateway(idx, slo_p99_ms=1e9)  # never under pressure
    try:
        r = gw.submit(_series(1, 5)[0], target_recall=0.95,
                      latency_budget_ms=0.05).result(timeout=30)
        assert r.conflict and r.shed and r.tier_served == "approx"
        ok = gw.submit(_series(1, 6)[0], target_recall=0.9).result(timeout=30)
        assert not ok.conflict and not ok.shed
    finally:
        gw.close()


def test_slo_shedding_engages_and_spares_strict_requests(idx):
    """With an impossible SLO the rolling p99 trips immediately: sheddable
    exact traffic downgrades to approx with shed provenance; strict
    (target_recall >= 1.0) requests keep the exact tier."""
    gw = _gateway(idx, slo_p99_ms=0.001, min_shed_samples=8,
                  deadline_ms=1.0, max_batch=8)
    try:
        Q = _series(40, 50)
        # prime the rolling window past min_shed_samples
        for i in range(16):
            gw.submit(Q[i]).result(timeout=30)
        assert gw.snapshot_stats()["shedding"]
        shed = gw.submit(Q[20]).result(timeout=30)
        assert shed.shed and shed.tier_served == "approx"
        assert shed.n_blocks == gw.cfg.shed_n_blocks
        strict = gw.submit(Q[21], target_recall=1.0).result(timeout=30)
        assert not strict.shed and strict.tier_served == "exact"
        # shed answers still match the direct approx call bitwise
        av, ai, _ = idx.knn_approx_batch(Q[20:21], k=5,
                                         n_blocks=shed.n_blocks)
        assert np.array_equal(shed.ids, ai[0])
        st = gw.snapshot_stats()
        assert st["shed_transitions"] >= 1 and st["shed_served"] >= 1
    finally:
        gw.close()


def test_shed_hysteresis_recovers():
    """Shedding must exit once the rolling p99 falls below the exit
    fraction of the SLO — exercised directly against the update rule."""
    idx2 = _index(n_batches=2, bsz=100)
    gw = _gateway(idx2, slo_p99_ms=50.0, min_shed_samples=4)
    try:
        with gw._cond:
            gw._lat_ms.extend([100.0] * 8)
            gw._update_shed_locked()
            assert gw._shedding
            gw._lat_ms.extend([1.0] * gw.cfg.lat_window)  # window rolls over
            gw._update_shed_locked()
            assert not gw._shedding
            assert gw.stats["shed_transitions"] == 2
    finally:
        gw.close()
        idx2.close()


def test_reset_slo_window_clears_shed_state():
    """Harnesses drop the warm-up latencies (one-time compiles) from the
    rolling window before measuring; the reset also leaves the shed state
    and counts as a transition."""
    idx2 = _index(n_batches=2, bsz=100)
    gw = _gateway(idx2, slo_p99_ms=50.0, min_shed_samples=4)
    try:
        with gw._cond:
            gw._lat_ms.extend([100.0] * 8)
            gw._update_shed_locked()
            assert gw._shedding
        gw.reset_slo_window()
        st = gw.snapshot_stats()
        assert not st["shedding"] and st["p99_ms"] == 0.0
        assert st["shed_transitions"] == 2
        gw.reset_slo_window()  # idempotent when not shedding
        assert gw.snapshot_stats()["shed_transitions"] == 2
    finally:
        gw.close()
        idx2.close()


def test_queue_wait_provenance_and_bounded_queue(idx):
    gw = _gateway(idx, deadline_ms=10.0)
    try:
        r = gw.submit(_series(1, 9)[0]).result(timeout=30)
        assert 0.0 <= r.queue_wait_ms <= r.latency_ms
    finally:
        gw.close()
    with pytest.raises(RuntimeError):
        gw.submit(_series(1, 9)[0])  # closed gateway rejects


# ------------------------------------------------------- integration tier
def test_concurrent_clients_with_background_ingest_parity():
    """The acceptance test: concurrent single-query clients against a
    background-ingest stream. During the live phase every response must be
    internally consistent (one pinned epoch per formed batch, monotone
    non-decreasing epochs, valid slates); after ingest quiesces, gateway
    answers must be bitwise-identical to direct batched calls at the same
    tier."""
    idx = _index(n_batches=4, bsz=250, ingest="async")
    gw = _gateway(idx, deadline_ms=4.0, max_batch=16)
    stop = threading.Event()

    def ingester():
        b = 4
        while not stop.is_set() and b < 10:
            idx.ingest(_series(250, 300 + b), np.full(250, b, np.int64))
            b += 1
            time.sleep(0.005)

    results = {}
    errs = []

    def client(cid):
        try:
            rng = np.random.default_rng(1000 + cid)
            out = []
            for j in range(6):
                q = rng.standard_normal(LEN).astype(np.float32).cumsum()
                kw = {}
                if j % 3 == 1:
                    kw["target_recall"] = 0.9
                if j % 2 == 1:
                    kw["window"] = (0, 3)
                out.append((q, kw, gw.submit(q, **kw).result(timeout=120)))
            results[cid] = out
        except BaseException as e:  # noqa: BLE001 — surface in main thread
            errs.append(e)

    ing = threading.Thread(target=ingester)
    clients = [threading.Thread(target=client, args=(c,)) for c in range(6)]
    ing.start()
    for t in clients:
        t.start()
    for t in clients:
        t.join(timeout=180)
    stop.set()
    ing.join(timeout=60)
    try:
        assert not errs, errs
        # live-phase invariants: sorted slates, valid ids, batch-level epochs
        by_batch = {}
        for out in results.values():
            for _, _, r in out:
                assert r.vals.shape == (5,) and r.ids.shape == (5,)
                assert (np.diff(r.vals) >= 0).all()
                assert (r.ids >= 0).all()  # k << live entries: full slates
                by_batch.setdefault((r.epoch, r.batch_size,
                                     round(r.queue_wait_ms, 6)), 0)
        # quiesced phase: ingest drained -> parity must be bitwise
        idx.drain(timeout=120)
        Q = _series(10, 999)
        resps = [t.result(timeout=60) for t in
                 [gw.submit(q) for q in Q[:5]] +
                 [gw.submit(q, target_recall=0.9) for q in Q[5:]]]
        ev, ei, _ = idx.knn_batch(Q[:5], k=5)
        nb = resps[5].n_blocks
        av, ai, _ = idx.knn_approx_batch(Q[5:], k=5, n_blocks=nb)
        for i in range(5):
            assert np.array_equal(resps[i].ids, ei[i])
            assert np.array_equal(resps[i].vals, ev[i])
            assert np.array_equal(resps[5 + i].ids, ai[i])
            assert np.array_equal(resps[5 + i].vals, av[i])
    finally:
        gw.close()
        idx.close()


def test_prewarmed_gateway_serves_with_zero_retraces():
    """After ``Gateway.prewarm`` covers the stream's table sizes, serving
    across every rung — including deadline-flushed padded batches — must
    not retrace."""
    idx = _index(n_batches=6, bsz=400)
    gw = _gateway(idx, deadline_ms=2.0, max_batch=16)
    engine = get_engine()
    try:
        gw.prewarm([400 * (b + 1) for b in range(6)])
        before = engine.stats["traces"]
        for m in (1, 4, 8, 11, 16):
            Q = _series(m, 600 + m)
            for t in [gw.submit(q) for q in Q]:
                t.result(timeout=60)
        assert engine.stats["traces"] == before
        # the engine-side served-batch histogram moved (monotonic counter)
        assert sum(engine.stats["batch_hist"].values()) > 0
    finally:
        gw.close()
        idx.close()


def test_engine_batch_hist_is_monotonic(idx):
    engine = get_engine()
    h0 = dict(engine.stats["batch_hist"])
    vals, gids, _ = idx.knn_batch(_series(16, 42), k=5)
    h1 = dict(engine.stats["batch_hist"])
    assert all(h1.get(kk, 0) >= v for kk, v in h0.items())
    assert sum(h1.values()) >= sum(h0.values())


# ------------------------------------------------------------ typed stats
def test_snapshot_is_typed_and_dict_view_matches(idx):
    """snapshot() returns the frozen GatewayStats; snapshot_stats() is its
    exact dict rendering (the old surface, kept for log emitters)."""
    import dataclasses as dc

    from repro.core import GatewayStats

    gw = _gateway(idx)
    try:
        for i in range(5):
            gw.submit(_series(1, 40 + i)[0]).result(timeout=60)
        snap = gw.snapshot()
        assert isinstance(snap, GatewayStats)
        assert dc.asdict(gw.snapshot()) == gw.snapshot_stats()
        assert snap.served == 5 and snap.submitted == 5
        assert not snap.autotune and snap.tuner_decisions == 0
        with pytest.raises(dc.FrozenInstanceError):
            snap.served = 0
        # the dict view keeps the pre-redesign key set (+ the tuner block)
        keys = set(gw.snapshot_stats())
        assert {"served", "submitted", "batches", "queue_depth", "shedding",
                "p50_ms", "p99_ms", "batch_hist", "autotune"} <= keys
    finally:
        gw.close()
