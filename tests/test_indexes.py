"""CTree / CLSM / ADS+ behaviour: exactness vs brute force, I/O profiles,
materialization variants, insert gaps, level structure."""
import numpy as np
import pytest

from repro.core import (
    ADSConfig,
    ADSIndex,
    CLSM,
    CLSMConfig,
    CTree,
    CTreeConfig,
    DiskModel,
    RawStore,
    SummarizationConfig,
    ed2,
)

CFG = SummarizationConfig(series_len=64, n_segments=8, card_bits=6)


def _data(n=4000, seed=0):
    rng = np.random.default_rng(seed)
    return rng.standard_normal((n, 64)).astype(np.float32).cumsum(axis=1)


def _queries(m=5, seed=99):
    rng = np.random.default_rng(seed)
    return rng.standard_normal((m, 64)).astype(np.float32).cumsum(axis=1)


@pytest.mark.parametrize("materialized", [False, True])
def test_ctree_exact_matches_brute_force(materialized):
    X, Q = _data(), _queries()
    disk = DiskModel()
    raw = RawStore(64, disk)
    ids = raw.append(X)
    ct = CTree(CTreeConfig(summarization=CFG, block_size=256, materialized=materialized,
                           mem_budget_entries=1111), disk)
    ct.bulk_build(X, ids)
    for q in Q:
        res, _ = ct.knn_exact(q, k=7, raw=raw)
        bf = np.sort(ed2(q, X))[:7]
        np.testing.assert_allclose([d for d, _ in res], bf, rtol=1e-4)


def test_ctree_approx_visits_few_blocks():
    X, Q = _data(), _queries()
    raw = RawStore(64)
    ids = raw.append(X)
    ct = CTree(CTreeConfig(summarization=CFG, block_size=128, materialized=True))
    ct.bulk_build(X, ids)
    res, st = ct.knn_approx(Q[0], k=1, n_blocks=2, raw=raw)
    assert st.blocks_visited <= 2 and len(res) == 1
    # approximate answer should be decent: within 3x of true NN distance
    bf = np.sort(ed2(Q[0], X))[0]
    assert res[0][0] <= 9 * bf + 1e-3


def test_ctree_insert_gaps_then_rebuild():
    X = _data(2000)
    extra = _data(900, seed=7)
    raw = RawStore(64)
    ids = raw.append(X)
    ct = CTree(CTreeConfig(summarization=CFG, block_size=128, fill_factor=0.75,
                           materialized=True))
    ct.bulk_build(X, ids)
    cap = ct.gap_capacity
    assert cap > 0
    ids2 = raw.append(extra)
    rebuilt = ct.insert(extra, ids2)
    assert rebuilt == (900 > cap)
    q = _queries(1)[0]
    allX = np.concatenate([X, extra])
    res, _ = ct.knn_exact(q, k=3, raw=raw)
    bf = np.sort(ed2(q, allX))[:3]
    np.testing.assert_allclose([d for d, _ in res], bf, rtol=1e-4)


def test_ctree_build_uses_sequential_io_only():
    X = _data()
    disk = DiskModel()
    raw = RawStore(64, disk)
    ids = raw.append(X)
    ct = CTree(CTreeConfig(summarization=CFG, mem_budget_entries=500), disk)
    ct.bulk_build(X, ids)
    assert disk.stats.rand_read_bytes == 0 and disk.stats.rand_write_bytes == 0


def test_clsm_exact_across_merges():
    X = _data(6000)
    cfg = CLSMConfig(summarization=CFG, buffer_entries=512, growth_factor=3,
                     block_size=128, materialized=True)
    lsm = CLSM(cfg)
    raw = RawStore(64)
    for i in range(0, 6000, 500):
        chunk = X[i : i + 500]
        ids = raw.append(chunk)
        lsm.insert(chunk, ids, np.full(len(chunk), i, np.int64))
    assert lsm.n_merges > 0
    assert lsm.n_runs < lsm.n_flushes  # merging bounded the run count
    q = _queries(1)[0]
    res, _ = lsm.knn_exact(q, k=5, raw=raw)
    bf = np.sort(ed2(q, X))[:5]
    np.testing.assert_allclose([d for d, _ in res], bf, rtol=1e-4)


def test_clsm_growth_factor_tradeoff():
    """Higher growth factor => fewer merges (cheaper writes), more runs
    (costlier reads) — the paper's read/write knob."""
    X = _data(8000)

    def build(t):
        lsm = CLSM(CLSMConfig(summarization=CFG, buffer_entries=256,
                              growth_factor=t, block_size=128))
        raw = RawStore(64)
        for i in range(0, 8000, 256):
            c = X[i : i + 256]
            lsm.insert(c, raw.append(c), np.full(len(c), i, np.int64))
        return lsm

    small, large = build(2), build(8)
    assert small.merged_bytes > large.merged_bytes
    assert small.n_runs <= large.n_runs


@pytest.mark.parametrize("mode", ["full", "adaptive"])
def test_adsplus_exact_matches_brute_force(mode):
    X, Q = _data(3000), _queries(3)
    raw = RawStore(64)
    ids = raw.append(X)
    ads = ADSIndex(ADSConfig(summarization=CFG, leaf_size=256, mode=mode,
                             query_leaf_size=64))
    ads.insert_batch(X, ids)
    for q in Q:
        res, _ = ads.knn_exact(q, k=5, raw=raw)
        bf = np.sort(ed2(q, X))[:5]
        np.testing.assert_allclose([d for d, _ in res], bf, rtol=1e-4)


def test_adsplus_insert_is_random_io_but_ctree_is_not():
    """The paper's central claim, in miniature: top-down insertion does
    random I/O per entry; Coconut's bottom-up build is sequential only."""
    X = _data(2000)
    d_ads = DiskModel()
    ads = ADSIndex(ADSConfig(summarization=CFG, leaf_size=128), d_ads)
    ads.insert_batch(X, np.arange(2000))
    d_ct = DiskModel()
    ct = CTree(CTreeConfig(summarization=CFG), d_ct)
    ct.bulk_build(X, np.arange(2000))
    assert d_ads.stats.rand_ops > 2000  # >= one random page op per insert
    assert d_ct.stats.rand_ops == 0
    assert d_ct.modeled_seconds() < d_ads.modeled_seconds()


def test_adaptive_splits_happen_at_query_time():
    X = _data(3000)
    raw = RawStore(64)
    ids = raw.append(X)
    ads = ADSIndex(ADSConfig(summarization=CFG, leaf_size=4096, mode="adaptive",
                             query_leaf_size=128))
    ads.insert_batch(X, ids)
    before = ads.n_splits
    ads.knn_exact(_queries(1)[0], k=1, raw=raw)
    assert ads.n_splits > before
