"""Crash-consistent file storage: WAL record mechanics, model-vs-file
answer parity, crash-injection recovery, readahead, measured counters.

The contract under test: (1) the file backend answers bitwise-identically
to the modeled backend on every tier — it is a storage engine, not a
different index; (2) a crash at ANY injected point between a WAL append
and a manifest commit recovers to exactly the acknowledged entry set,
answering bitwise-equal to an uncrashed index over the same entries; (3)
torn/corrupt WAL tails truncate to the good prefix instead of erroring."""
import glob
import json
import os

import numpy as np
import pytest

from repro.core import (
    SimulatedCrash,
    StreamConfig,
    StreamingIndex,
    SummarizationConfig,
)
from repro.core.run_registry import BufferChunk, RunRegistry
from repro.core.storage.wal import WriteAheadLog, replay_file

CFG = SummarizationConfig(series_len=64, n_segments=8, card_bits=6)


def _series(n, seed):
    rng = np.random.default_rng(seed)
    return rng.standard_normal((n, 64)).astype(np.float32).cumsum(axis=1)


def _chunk(n, seed, id0=0, t=0):
    return BufferChunk(series=_series(n, seed),
                       ids=np.arange(id0, id0 + n, dtype=np.int64),
                       ts=np.full(n, t, np.int64))


def _stream_cfg(tmp_path=None, backend="model", **kw):
    kw.setdefault("buffer_entries", 64)
    kw.setdefault("block_size", 32)
    kw.setdefault("growth_factor", 2)
    return StreamConfig(scheme="BTP", summarization=CFG, storage=backend,
                        storage_dir=None if tmp_path is None else str(tmp_path),
                        **kw)


def _batches(n_batch, bsz, seed0=0):
    out, t = [], 0
    for b in range(n_batch):
        out.append((_series(bsz, seed0 + b), np.arange(t, t + bsz, dtype=np.int64)))
        t += bsz
    return out


# ---------------------------------------------------------------------------
# WAL record mechanics
# ---------------------------------------------------------------------------
def test_wal_roundtrip_with_and_without_ts(tmp_path):
    wal = WriteAheadLog(str(tmp_path), series_len=64)
    wal.open(0)
    c1 = _chunk(10, seed=1)
    c2 = BufferChunk(series=_series(5, 2), ids=np.arange(10, 15, dtype=np.int64))
    wal.append(c1)
    wal.append(c2)
    wal.close()
    chunks, good = replay_file(wal.path(0), 64)
    assert good == os.path.getsize(wal.path(0))
    assert len(chunks) == 2
    np.testing.assert_array_equal(chunks[0].series, c1.series)
    np.testing.assert_array_equal(chunks[0].ids, c1.ids)
    np.testing.assert_array_equal(chunks[0].ts, c1.ts)
    np.testing.assert_array_equal(chunks[1].series, c2.series)
    assert chunks[1].ts is None


def test_wal_torn_tail_is_truncated_on_open(tmp_path):
    wal = WriteAheadLog(str(tmp_path), series_len=64)
    wal.open(0)
    wal.append(_chunk(8, seed=3))
    wal.append(_chunk(8, seed=4, id0=8))
    wal.close()
    full = os.path.getsize(wal.path(0))
    with open(wal.path(0), "r+b") as f:  # tear the second record mid-payload
        f.truncate(full - 37)
    wal2 = WriteAheadLog(str(tmp_path), series_len=64)
    chunks = wal2.open(0)
    assert len(chunks) == 1 and chunks[0].n == 8
    # the torn tail is physically gone: appends continue from a clean prefix
    wal2.append(_chunk(4, seed=5, id0=8))
    wal2.close()
    chunks, _ = replay_file(wal2.path(0), 64)
    assert [c.n for c in chunks] == [8, 4]


def test_wal_corrupt_record_drops_it_and_everything_after(tmp_path):
    wal = WriteAheadLog(str(tmp_path), series_len=64)
    wal.open(0)
    sizes = []
    for i in range(3):
        wal.append(_chunk(6, seed=10 + i, id0=6 * i))
        sizes.append(os.path.getsize(wal.path(0)))
    wal.close()
    with open(wal.path(0), "r+b") as f:  # flip a payload byte of record 2
        f.seek(sizes[0] + 40)
        b = f.read(1)
        f.seek(sizes[0] + 40)
        f.write(bytes([b[0] ^ 0xFF]))
    chunks, good = replay_file(wal.path(0), 64)
    assert len(chunks) == 1 and good == sizes[0]  # record 3 goes too


def test_wal_truncate_front_splits_partial_record(tmp_path):
    wal = WriteAheadLog(str(tmp_path), series_len=64)
    wal.open(0)
    wal.append(_chunk(10, seed=20, id0=0))
    wal.append(_chunk(10, seed=21, id0=10))
    old = wal.truncate_front(13)  # splits the second record at entry 3
    assert old.endswith("wal-00000000.log") and wal.log_id == 1
    assert wal.entries == 7
    survivors = wal.chunks()
    assert len(survivors) == 1
    np.testing.assert_array_equal(survivors[0].ids, np.arange(13, 20))
    # the rotated file replays to the same survivors
    chunks, _ = replay_file(wal.path(1), 64)
    np.testing.assert_array_equal(chunks[0].ids, np.arange(13, 20))
    wal.close()


# ---------------------------------------------------------------------------
# model-vs-file answer parity
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("materialized", [False, True])
def test_file_backend_answers_match_model_backend(tmp_path, materialized):
    """Same stream, same queries, both tiers: the file backend is pure
    storage — every answer is bitwise-equal to the modeled backend's."""
    Q = _series(4, seed=999)
    answers = {}
    for backend in ("model", "file"):
        cfg = _stream_cfg(tmp_path / backend if backend == "file" else None,
                          backend, materialized=materialized)
        idx = StreamingIndex(cfg)
        for S, ts in _batches(6, 40, seed0=100):
            idx.ingest(S, ts)
        exact = idx.window_knn_batch(Q, 0, 10**9, k=5)
        approx = idx.window_knn_approx_batch(Q, 50, 200, k=5, n_blocks=2)
        answers[backend] = (exact, approx)
    for tier in range(2):
        vals_m, ids_m, _ = answers["model"][tier]
        vals_f, ids_f, _ = answers["file"][tier]
        np.testing.assert_array_equal(vals_m, vals_f)
        np.testing.assert_array_equal(ids_m, ids_f)


# ---------------------------------------------------------------------------
# recovery
# ---------------------------------------------------------------------------
def test_fresh_directory_recovers_empty(tmp_path):
    idx = StreamingIndex.recover(_stream_cfg(), str(tmp_path))
    assert idx.raw.n == 0 and idx.n_partitions == 0
    idx.ingest(_series(10, seed=0), np.arange(10, dtype=np.int64))
    assert idx.raw.n == 10


def test_clean_reopen_preserves_answers_and_id_sequence(tmp_path):
    cfg = _stream_cfg(tmp_path, "file")
    idx = StreamingIndex(cfg)
    for S, ts in _batches(5, 40, seed0=200):
        idx.ingest(S, ts)
    Q = _series(3, seed=998)
    vals, gids, _ = idx.window_knn_batch(Q, 0, 10**9, k=5)
    n = idx.raw.n

    idx2 = StreamingIndex.recover(_stream_cfg(), str(tmp_path))
    assert idx2.raw.n == n
    v2, g2, _ = idx2.window_knn_batch(Q, 0, 10**9, k=5)
    np.testing.assert_array_equal(vals, v2)
    np.testing.assert_array_equal(gids, g2)
    # ids keep ascending from the durable extent
    ids = idx2.ingest(_series(8, seed=201), np.arange(n, n + 8, dtype=np.int64))
    np.testing.assert_array_equal(ids, np.arange(n, n + 8))


def _crash_then_recover(tmp_path, point, crash_batch, batches, Q, k=5):
    """Ingest until ``point`` fires at ``crash_batch``; recover; return the
    recovered index + how many batches were fully acknowledged."""
    cfg = _stream_cfg(tmp_path, "file")
    idx = StreamingIndex(cfg)
    n_ok = 0
    for i, (S, ts) in enumerate(batches):
        if i == crash_batch:
            idx.storage.crash_after = point
        try:
            idx.ingest(S, ts)
            n_ok += 1
        except SimulatedCrash:
            break
    else:
        raise AssertionError(f"crash point {point!r} never fired")
    # the process is gone; recover from the directory alone
    return StreamingIndex.recover(_stream_cfg(), str(tmp_path)), n_ok


def _assert_equals_uncrashed(tmp_path, rec_idx, n_batches, batches, Q, k=5):
    """The recovered index answers bitwise-equal to an uncrashed index that
    ingested exactly the acknowledged batches."""
    ctl = StreamingIndex(_stream_cfg(tmp_path / "control", "file"))
    for S, ts in batches[:n_batches]:
        ctl.ingest(S, ts)
    assert rec_idx.raw.n == ctl.raw.n
    for idx in (rec_idx, ctl):
        idx_vals, idx_ids, _ = idx.window_knn_batch(Q, 0, 10**9, k=k)
        idx.answers = (idx_vals, idx_ids)  # noqa: B010 — test-local stash
    np.testing.assert_array_equal(rec_idx.answers[0], ctl.answers[0])
    np.testing.assert_array_equal(rec_idx.answers[1], ctl.answers[1])


@pytest.mark.parametrize("point", ["wal-append", "pre-manifest"])
def test_crash_recovery_quick(tmp_path, point):
    """Tier-1 cut of the crash sweep: one point before any commit, one
    between run-publish and manifest commit."""
    batches = _batches(6, 40, seed0=300)
    Q = _series(3, seed=997)
    rec, n_ok = _crash_then_recover(tmp_path, point, 3, batches, Q)
    # the crashed batch WAS WAL-appended before every injected point fired,
    # so it is part of the acknowledged durable set
    _assert_equals_uncrashed(tmp_path, rec, n_ok + 1, batches, Q)


@pytest.mark.slow
@pytest.mark.parametrize("point,crash_batch", [
    ("wal-append", 3), ("flush-taken", 3), ("run-persisted", 3),
    ("pre-manifest", 3), ("post-manifest", 3),
    ("merge-pre-manifest", 6), ("merge-post-manifest", 6),
])
def test_crash_recovery_sweep(tmp_path, point, crash_batch):
    """Every injected point between WAL append and manifest commit (flush
    AND merge paths): recovery equals the uncrashed run bitwise, and
    ingest can continue afterwards with the same equality."""
    batches = _batches(10, 40, seed0=400)
    Q = _series(3, seed=996)
    rec, n_ok = _crash_then_recover(tmp_path, point, crash_batch, batches, Q)
    acked = n_ok + 1
    _assert_equals_uncrashed(tmp_path, rec, acked, batches, Q)
    # life goes on: ingest the remaining batches into the recovered index
    # and a fresh control; answers stay bitwise-equal
    ctl = StreamingIndex(_stream_cfg(tmp_path / "resumed", "file"))
    for S, ts in batches[:acked]:
        ctl.ingest(S, ts)
    for S, ts in batches[acked:]:
        rec.ingest(S, ts)
        ctl.ingest(S, ts)
    rv, ri, _ = rec.window_knn_batch(Q, 0, 10**9, k=5)
    cv, ci, _ = ctl.window_knn_batch(Q, 0, 10**9, k=5)
    np.testing.assert_array_equal(rv, cv)
    np.testing.assert_array_equal(ri, ci)


def test_orphan_run_dirs_and_stale_wals_are_deleted(tmp_path):
    cfg = _stream_cfg(tmp_path, "file")
    idx = StreamingIndex(cfg)
    for S, ts in _batches(4, 40, seed0=500):
        idx.ingest(S, ts)
    runs_dir = tmp_path / "runs"
    os.makedirs(runs_dir / "run-99999999")
    (runs_dir / "run-99999999" / "meta.json").write_text("{}")
    stale = tmp_path / "wal" / "wal-00000099.log"
    stale.write_bytes(b"junk")
    idx2 = StreamingIndex.recover(_stream_cfg(), str(tmp_path))
    assert not (runs_dir / "run-99999999").exists()
    assert not stale.exists()
    assert idx2.raw.n == idx.raw.n


def test_manifest_is_valid_json_and_names_live_runs(tmp_path):
    idx = StreamingIndex(_stream_cfg(tmp_path, "file"))
    for S, ts in _batches(4, 40, seed0=600):
        idx.ingest(S, ts)
    man = json.loads((tmp_path / "MANIFEST.json").read_text())
    named = {name for _, names in man["levels"] for name in names}
    on_disk = {os.path.basename(p) for p in glob.glob(str(tmp_path / "runs" / "*"))}
    assert named == on_disk  # every named run exists, no unnamed leftovers
    live = {os.path.basename(r._storage.dir)
            for r in idx.lsm.registry.current().runs_newest_first()}
    assert named == live


# ---------------------------------------------------------------------------
# readahead + measured counters + restore mechanics
# ---------------------------------------------------------------------------
def test_prefetch_counters_advance_and_answers_unchanged(tmp_path):
    from repro.core.storage.prefetch import get_pool

    idx = StreamingIndex(_stream_cfg(tmp_path, "file"))
    for S, ts in _batches(6, 40, seed0=700):
        idx.ingest(S, ts)
    pool = get_pool()
    before = pool.stats()["prefetch_spans"]
    Q = _series(4, seed=995)
    vals, gids, _ = idx.window_knn_approx_batch(Q, 0, 10**9, k=5, n_blocks=2)
    pool.drain()
    stats = pool.stats()
    assert stats["prefetch_spans"] > before
    assert stats["prefetch_errors"] == 0
    # readahead is advisory: a second identical query answers identically
    v2, g2, _ = idx.window_knn_approx_batch(Q, 0, 10**9, k=5, n_blocks=2)
    np.testing.assert_array_equal(vals, v2)
    np.testing.assert_array_equal(gids, g2)


def test_measured_counters_populated(tmp_path):
    idx = StreamingIndex(_stream_cfg(tmp_path, "file"))
    for S, ts in _batches(4, 40, seed0=800):
        idx.ingest(S, ts)
    idx.window_knn_batch(_series(2, seed=994), 0, 10**9, k=3)
    m = idx.measured_io()
    assert m["raw_write_bytes"] == idx.raw.n * 64 * 4
    assert m["wal_records"] == 4
    assert m["wal_write_bytes"] > 0
    assert m["run_write_bytes"] > 0
    assert m["manifest_commits"] > 0
    assert m["raw_read_bytes"] > 0
    # the modeled backend measures nothing
    assert StreamingIndex(_stream_cfg()).measured_io() == {}


def test_registry_restore_is_one_epoch_bump_and_guards_nonempty():
    reg = RunRegistry()
    e0 = reg.current().epoch
    snap = reg.restore([(0, [object()]), (1, [object(), object()])],
                       [_chunk(5, seed=900)])
    assert snap.epoch == e0 + 1  # ONE bump for the whole recovered state
    assert snap.n_runs == 3 and snap.buffer_n == 5 and snap.flushing == ()
    with pytest.raises(ValueError):
        reg.restore([], [_chunk(1, seed=901)])
