"""Demo scenario 1 — Big Static Data Series (paper §5).

A large collection of astronomy-like series is explored for known patterns.
We first run the state-of-the-art baseline (ADS+), then consult the
recommender and rerun with its choice (non-materialized CTree + PP),
visualizing construction cost, query cost, and the access-pattern heat map
that explains WHY the sorted contiguous layout wins.

    PYTHONPATH=src python examples/static_exploration.py
"""
import time

import numpy as np

from repro.core import (
    ADSConfig, ADSIndex, CTree, CTreeConfig, DiskModel, RawStore, Scenario,
    SummarizationConfig, recommend, render_heatmap,
)
from repro.data.synthetic import astronomy

N, LEN = 30_000, 256
CFG = SummarizationConfig(series_len=LEN, n_segments=16, card_bits=8)


def explore(name, build_fn, queries):
    disk = DiskModel(keep_log=True)
    raw = RawStore(LEN, disk)
    t0 = time.time()
    index = build_fn(raw, disk)
    build_s = time.time() - t0
    build_io = disk.modeled_seconds()
    build_rand = disk.stats.rand_ops
    disk.reset()
    t0 = time.time()
    results = [index.knn_exact(q, k=5, raw=raw) for q in queries]
    query_s = (time.time() - t0) / len(queries)
    print(f"{name:28s} build {build_s:6.2f}s (modeled io {build_io:7.2f}s, "
          f"{build_rand:7d} random ops) | query {query_s*1e3:7.1f} ms")
    print(f"{'':28s} access pattern: {render_heatmap(disk.heatmap())}")
    return [r[0] for r in results]


def main():
    print(f"== Scenario 1: exploring {N} astronomy series for known patterns ==\n")
    X = astronomy(N, LEN, seed=0)
    queries = astronomy(8, LEN, seed=123)  # 'supernova', 'binary star', ...

    def build_ads(raw, disk):
        ids = raw.append(X)
        idx = ADSIndex(ADSConfig(summarization=CFG, leaf_size=2048,
                                 mode="adaptive", query_leaf_size=256), disk)
        idx.insert_batch(X, ids)
        return idx

    r_ads = explore("ADS+ (state of the art)", build_ads, queries)

    rec = recommend(Scenario(streaming=False, n_series=N, series_len=LEN,
                             expected_queries=len(queries), uses_windows=False))
    print("\nrecommender says:", rec.describe(), "\n")

    def build_ct(raw, disk):
        ids = raw.append(X)
        idx = CTree(CTreeConfig(summarization=CFG, block_size=1024,
                                materialized=rec.materialized,
                                mem_budget_entries=rec.mem_budget_entries), disk)
        idx.bulk_build(X, ids)
        return idx

    r_ct = explore("CTree (recommended)", build_ct, queries)
    print("   (non-materialized: index scan is sequential; the scattered "
          "touches are raw-file fetches for the few verified candidates)\n")

    def build_ct_mat(raw, disk):
        ids = raw.append(X)
        idx = CTree(CTreeConfig(summarization=CFG, block_size=1024,
                                materialized=True), disk)
        idx.bulk_build(X, ids)
        return idx

    explore("CTree (materialized)", build_ct_mat, queries)

    agree = all(
        np.isclose([d for d, _ in a], [d for d, _ in b], rtol=1e-4).all()
        for a, b in zip(r_ads, r_ct)
    )
    print(f"\nanswers identical across indexes: {agree}")

    # the paper's follow-up: many queries flip the choice to materialized
    rec2 = recommend(Scenario(streaming=False, n_series=N, series_len=LEN,
                              expected_queries=10**6))
    print(f"with 1e6 expected queries the recommender flips to: "
          f"{'materialized' if rec2.materialized else 'non-materialized'} CTree")

    # -------------------------------------------------------------------
    # The approximate exploration tier — the payoff the demo is named for.
    # Sorted keys turn approximate search into one key seek plus one
    # sequential block read per query; batched, the whole query batch
    # shares one vectorized seek and coalesced sequential reads. Results
    # are a SUBSET of the exact answer (only each query's n_blocks
    # adjacent blocks are verified), so n_blocks is the knob trading
    # sequential bytes read per query for recall@k.
    print("\n== Approximate tier: recall@5 vs sequential I/O (batched) ==")
    disk = DiskModel(keep_log=True)
    raw = RawStore(LEN, disk)
    ids = raw.append(X)
    ct = CTree(CTreeConfig(summarization=CFG, block_size=1024,
                           materialized=True), disk)
    ct.bulk_build(X, ids)
    # knn_batch / knn_approx_batch default to backend="device" since PR 4:
    # verification runs as fused passes over a device-resident arena
    # (answers identical to backend="numpy" — certified, with a host
    # fallback below the engine's size floors)
    _, exact_ids, _ = ct.knn_batch(queries, k=5, raw=raw)
    seek_bins = None
    for n_blocks in (1, 2, 4, 8):
        disk.reset()
        t0 = time.time()
        _, approx_ids, _ = ct.knn_approx_batch(queries, k=5,
                                               n_blocks=n_blocks, raw=raw)
        ms = (time.time() - t0) / len(queries) * 1e3
        hits = sum(len(set(map(int, a)) & set(map(int, e)))
                   for a, e in zip(approx_ids, exact_ids))
        recall = hits / (5 * len(queries))
        print(f"  n_blocks={n_blocks}: recall@5={recall:.2f}  "
              f"{ms:6.2f} ms/query  seq={disk.stats.seq_read_bytes >> 10} KiB  "
              f"rand_ops={disk.stats.rand_ops}")
        if seek_bins is None:
            seek_bins = disk.heatmap()
    print("   access pattern (n_blocks=1):", render_heatmap(seek_bins))
    print("   (a few contiguous stripes — one coalesced sequential read per "
          "query neighborhood,\n    vs the exact tier's scattered verification "
          "fetches above)")


if __name__ == "__main__":
    main()
