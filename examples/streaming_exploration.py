"""Demo scenario 2 — Dynamic Streaming Data Series (paper §5). This is the
END-TO-END SERVING DRIVER: seismic batches arrive continuously; the system
serves batched variable-window nearest-neighbor queries (find earthquake
patterns) while ingesting.

Baseline = ADS+ with PP (post-filter) and TP-style partitioning; ours =
the recommender's choice, non-materialized CLSM + BTP.

    PYTHONPATH=src python examples/streaming_exploration.py
"""
import time

import numpy as np

from repro.core import (
    ADSConfig, ADSIndex, DiskModel, RawStore, Scenario, StreamConfig,
    StreamingIndex, SummarizationConfig, ed2, recommend, render_heatmap,
)
from repro.data.synthetic import seismic

LEN, BATCHES, BSZ, QB = 128, 60, 500, 8
CFG = SummarizationConfig(series_len=LEN, n_segments=16, card_bits=8)


def run_coconut(scheme, growth):
    idx = StreamingIndex(StreamConfig(scheme=scheme, summarization=CFG,
                                      buffer_entries=2048, growth_factor=growth,
                                      block_size=512))
    idx.raw.disk.keep_log = True
    ingest_s = query_ms = 0.0
    checks = 0
    for b in range(BATCHES):
        x = seismic(BSZ, LEN, seed=b)
        t0 = time.time()
        idx.ingest(x, np.full(BSZ, b, np.int64))
        ingest_s += time.time() - t0
        if (b + 1) % 10 == 0:
            qs = seismic(QB, LEN, seed=5_000 + b, quake_frac=1.0)  # quake patterns
            t0 = time.time()
            for q in qs:
                idx.window_knn(q, max(0, b - 8), b, k=3)
            query_ms += (time.time() - t0) * 1e3 / QB
            checks += 1
    return idx, ingest_s, query_ms / checks


def run_ads_pp():
    """Baseline: top-down iSAX tree, window handled by post-filtering."""
    disk = DiskModel(keep_log=True)
    raw = RawStore(LEN, disk)
    ads = ADSIndex(ADSConfig(summarization=CFG, leaf_size=1024), disk)
    ingest_s = query_ms = 0.0
    checks = 0
    for b in range(BATCHES):
        x = seismic(BSZ, LEN, seed=b)
        t0 = time.time()
        ads.insert_batch(x, raw.append(x), np.full(BSZ, b, np.int64))
        ingest_s += time.time() - t0
        if (b + 1) % 10 == 0:
            qs = seismic(QB, LEN, seed=5_000 + b, quake_frac=1.0)
            t0 = time.time()
            for q in qs:
                ads.knn_exact(q, k=3, raw=raw, window=(max(0, b - 8), b))
            query_ms += (time.time() - t0) * 1e3 / QB
            checks += 1
    return ads, disk, ingest_s, query_ms / checks


def main():
    print(f"== Scenario 2: {BATCHES} batches x {BSZ} seismic series, "
          f"window queries while ingesting ==\n")

    rec = recommend(Scenario(streaming=True, n_series=BATCHES * BSZ,
                             series_len=LEN, uses_windows=True, ingest_rate=1e4))
    print("recommender says:", rec.describe(), "\n")

    ads, ads_disk, ai, aq = run_ads_pp()
    print(f"ADS+ (PP baseline)     ingest {ai:6.2f}s "
          f"(modeled io {ads_disk.modeled_seconds():7.2f}s) | "
          f"window query {aq:7.1f} ms")
    print(f"{'':23s}heat map: {render_heatmap(ads_disk.heatmap())}")

    for scheme in ("TP", "BTP"):
        idx, ci, cq = run_coconut(scheme, rec.growth_factor)
        print(f"CLSM + {scheme:3s}            ingest {ci:6.2f}s "
              f"(modeled io {idx.raw.disk.modeled_seconds():7.2f}s) | "
              f"window query {cq:7.1f} ms | partitions={idx.n_partitions}")
        print(f"{'':23s}heat map: {render_heatmap(idx.raw.disk.heatmap())}")

    # correctness spot-check: BTP answer == brute force over the window
    idx, _, _ = run_coconut("BTP", rec.growth_factor)
    X = np.concatenate([seismic(BSZ, LEN, seed=b) for b in range(BATCHES)])
    T = np.repeat(np.arange(BATCHES), BSZ)
    q = seismic(1, LEN, seed=5_059, quake_frac=1.0)[0]
    res, _ = idx.window_knn(q, 50, 59, k=3)
    m = (T >= 50) & (T <= 59)
    bf = np.sort(ed2(q, X[m]))[:3]
    ok = np.allclose([d for d, _ in res], bf, rtol=1e-4)
    print(f"\nBTP window answers match brute force: {ok}")


if __name__ == "__main__":
    main()
