"""Quickstart: build a Coconut index, run exact + approximate kNN.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core import (
    CTree, CTreeConfig, DiskModel, RawStore, SummarizationConfig, ed2,
)
from repro.data.synthetic import random_walk


def main():
    cfg = SummarizationConfig(series_len=256, n_segments=16, card_bits=8)
    X = random_walk(20_000, 256, seed=0)
    q = random_walk(1, 256, seed=1)[0]

    disk = DiskModel()
    raw = RawStore(256, disk)
    ids = raw.append(X)

    index = CTree(CTreeConfig(summarization=cfg, block_size=1024,
                              materialized=True), disk)
    report = index.bulk_build(X, ids)
    print(f"built CTree over {report.n_entries} series "
          f"({report.n_runs} sorted runs, {report.n_passes} passes, "
          f"0 random I/Os)")

    exact, stats = index.knn_exact(q, k=5, raw=raw)
    print("exact 5-NN:", [(round(d, 1), i) for d, i in exact])
    print(f"  visited {stats.blocks_visited} blocks, "
          f"pruned {stats.blocks_pruned} blocks / {stats.entries_pruned} entries")

    approx, stats = index.knn_approx(q, k=5, n_blocks=2, raw=raw)
    print("approx 5-NN:", [(round(d, 1), i) for d, i in approx])
    print("  (2 contiguous blocks = one sequential read)")

    bf = float(np.sort(ed2(q, X))[0])
    print(f"true NN distance {bf:.1f}; exact found {exact[0][0]:.1f}; "
          f"approx found {approx[0][0]:.1f}")


if __name__ == "__main__":
    main()
