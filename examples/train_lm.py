"""Train a (reduced) LM end-to-end on this host: loss goes down, checkpoints
are written atomically, and a simulated crash + resume continues exactly.

    PYTHONPATH=src python examples/train_lm.py
"""
import shutil
import subprocess
import sys
import tempfile

TRAIN = [sys.executable, "-m", "repro.launch.train", "--arch", "smollm-360m",
         "--smoke", "--global-batch", "8", "--seq-len", "64", "--lr", "1e-3",
         "--warmup", "10", "--log-every", "20"]


def main():
    ckpt = tempfile.mkdtemp(prefix="coconut_ck_")
    try:
        print("== phase 1: train to step 120, crash at 90 (simulated failure) ==")
        r = subprocess.run(TRAIN + ["--steps", "120", "--ckpt-dir", ckpt,
                                    "--ckpt-every", "40", "--crash-at", "90"],
                           env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin"},
                           capture_output=True, text=True)
        print(r.stdout)
        assert r.returncode == 17, f"expected simulated crash, got {r.returncode}"

        print("== phase 2: relaunch — auto-resumes from the last checkpoint ==")
        r = subprocess.run(TRAIN + ["--steps", "120", "--ckpt-dir", ckpt,
                                    "--ckpt-every", "40"],
                           env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin"},
                           capture_output=True, text=True)
        print(r.stdout)
        assert r.returncode == 0, r.stderr[-1000:]
        assert "resumed from step 80" in r.stdout
        losses = [float(l.split("loss=")[1].split()[0])
                  for l in r.stdout.splitlines() if "loss=" in l]
        print(f"loss trajectory after resume: {losses}")
        # random init gives ~ln(49152) ~ 10.8; trained loss must be well below
        assert losses[-1] < 6.0, "loss should be well below random-init level"
        print("OK: crash/resume training works; loss far below init")
    finally:
        shutil.rmtree(ckpt, ignore_errors=True)


if __name__ == "__main__":
    main()
